"""Intensity-resident training ingestion (encode="kernel").

The trainer's claim: the dataset stays uint8[N, n_inputs] + per-sample
counter-hash seeds, and the N×T×w spike tensor is never materialized —
each presentation draws its window inside the kernels.  These tests pin
(a) that the pre-encoder is really never called, (b) that the
intensity-resident stream drivers are bit-exact with pre-packed windows
host-encoded from the same seeds, and (c) the seed-derivation contract.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.trainer as trainer_mod
from repro.core import lfsr
from repro.core.encoder import (encode_windows_host, quantize_intensities,
                                sample_seeds, sample_seeds_at)
from repro.core.rvsnn import snn_regfile, snn_regfile_batch
from repro.core.trainer import SNNTrainConfig, accuracy, classify, train
from repro.data.digits import make_digits
from repro.engine import (SNNEngine, SNNEnginePlan, train_stream,
                          train_stream_batch)

N, W, T, B = 20, 5, 10, 3
KW = dict(threshold=40, leak=3, w_exp=30, gain=4, n_syn=W * 32,
          ltp_prob=500)


def _stream_operands(seed=0):
    rng = np.random.default_rng(seed)
    weights = jnp.asarray(rng.integers(0, 2**32, (N, W), dtype=np.uint32))
    inten = jnp.asarray(rng.integers(0, 256, (4, W * 32), dtype=np.uint8))
    teach = jnp.asarray(rng.integers(-50, 50, (4, N), dtype=np.int32))
    seeds = sample_seeds(0x22A, 4)
    return weights, inten, teach, seeds


def test_sample_seeds_contract():
    s = sample_seeds(7, 16)
    assert s.dtype == jnp.int32 and s.shape == (16,)
    # stateless: any prefix/suffix regenerates identically
    np.testing.assert_array_equal(np.asarray(sample_seeds(7, 8)),
                                  np.asarray(s[:8]))
    # decorrelated, not consecutive integers; distinct per base seed
    assert len(set(np.asarray(s).tolist())) == 16
    assert (np.asarray(sample_seeds(8, 16)) != np.asarray(s)).any()


def test_sample_seeds_epoch_zero_is_bit_exact_with_legacy():
    """epoch defaults to 0 and reproduces the historical single-epoch
    derivation exactly — callers that never pass epoch see no change."""
    legacy = lfsr.counter_hash(jnp.uint32(7),
                               jnp.arange(16, dtype=jnp.uint32),
                               jnp.uint32(0)).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(sample_seeds(7, 16)),
                                  np.asarray(legacy))
    np.testing.assert_array_equal(np.asarray(sample_seeds(7, 16, 0)),
                                  np.asarray(legacy))


def test_sample_seeds_epochs_decorrelate():
    """Distinct epochs draw distinct seeds for every sample (fresh
    Poisson windows per epoch), and the derivation is stateless in
    (base, epoch, index)."""
    e0 = np.asarray(sample_seeds(7, 32, 0))
    e1 = np.asarray(sample_seeds(7, 32, 1))
    e2 = np.asarray(sample_seeds(7, 32, 2))
    assert (e0 != e1).all() and (e1 != e2).all() and (e0 != e2).all()
    np.testing.assert_array_equal(np.asarray(sample_seeds(7, 32, 1)), e1)


def test_sample_seeds_at_indexes_the_full_range():
    """sample_seeds_at(base, idx, e) == sample_seeds(base, n, e)[idx] —
    error-subset re-presentations keep each sample's original
    derivation without materializing the range."""
    idx = jnp.asarray([3, 0, 11, 11, 7], jnp.int32)
    for epoch in (0, 1, 5):
        full = np.asarray(sample_seeds(0x22A, 12, epoch))
        at = np.asarray(sample_seeds_at(0x22A, idx, epoch))
        np.testing.assert_array_equal(at, full[np.asarray(idx)])


def test_multi_epoch_kernel_training_uses_fresh_draws():
    """A second epoch must not just re-run epoch 0's windows: with
    epoch-keyed seeds, (epoch 0, epoch 1) ends in different weights
    than presenting epoch 0's windows twice — and the epoch-1 pass is
    itself deterministic."""
    weights, inten, teach, _ = _stream_operands()
    eng = SNNEngine(SNNEnginePlan(encode="kernel", **KW))
    e0 = sample_seeds(0x22A, 4, 0)
    e1 = sample_seeds(0x22A, 4, 1)

    def two_passes(second_seeds):
        rf = snn_regfile(weights, seed=0xACE1)
        for s in (e0, second_seeds):
            rf, _ = train_stream(eng, rf, teach=teach,
                                 intensities=inten, seeds=s,
                                 n_steps=T)
        return np.asarray(rf.weights)

    repeated = two_passes(e0)
    fresh = two_passes(e1)
    fresh2 = two_passes(e1)
    np.testing.assert_array_equal(fresh, fresh2)
    assert not np.array_equal(repeated, fresh)


def test_kernel_encode_never_materializes_spike_tensor(monkeypatch):
    """encode="kernel" must not call the dataset pre-encoder in either
    train mode — the whole point of intensity residency."""
    def boom(*a, **k):
        raise AssertionError("poisson_encode_batch called with "
                             "encode='kernel'")

    monkeypatch.setattr(trainer_mod, "poisson_encode_batch", boom)
    imgs, labels = make_digits(30, seed=5)
    for mode in ("active", "parallel"):
        cfg = SNNTrainConfig(n_neurons=20, epochs=1, n_steps=8,
                             encode="kernel", train_mode=mode)
        model = train(cfg, imgs, labels)
        assert model.weights.shape == (20, cfg.words)
        acc = accuracy(model,
                       intensities=quantize_intensities(jnp.asarray(imgs)),
                       labels=jnp.asarray(labels),
                       seeds=sample_seeds(cfg.encode_seed, len(imgs)))
        assert 0.0 <= acc <= 1.0


def test_train_stream_intensity_matches_prepacked():
    """The intensity-resident sample scan == the same samples host
    counter-encoded into packed windows, bit-exactly (same plan, same
    per-sample seeds)."""
    weights, inten, teach, seeds = _stream_operands(3)
    eng = SNNEngine(SNNEnginePlan(encode="kernel", **KW))
    rf_i, counts_i = train_stream(eng, snn_regfile(weights, seed=9),
                                  teach=teach, intensities=inten,
                                  seeds=seeds, n_steps=T)
    windows = encode_windows_host(seeds, inten, T, W)
    rf_w, counts_w = train_stream(eng, snn_regfile(weights, seed=9),
                                  windows, teach)
    for a, b in zip(rf_i, rf_w):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(counts_i),
                                  np.asarray(counts_w))


def test_train_stream_batch_intensity_matches_prepacked():
    """B block streams over the same intensity-resident samples == the
    broadcast pre-packed spike tensor, incl. the per-stream ltp_prob
    schedule."""
    rng = np.random.default_rng(13)
    weights, inten, teach, seeds = _stream_operands(4)
    wts_b = jnp.asarray(rng.integers(0, 2**32, (B, N, W),
                                     dtype=np.uint32))
    teach_b = jnp.broadcast_to(teach, (B,) + teach.shape)
    lp = jnp.asarray([16, 500, 1023], jnp.int32)
    eng = SNNEngine(SNNEnginePlan(encode="kernel", **KW))
    inten_b = jnp.broadcast_to(inten, (B,) + inten.shape)
    rfs_i, counts_i = train_stream_batch(
        eng, snn_regfile_batch(wts_b, [4, 5, 6]), teach=teach_b,
        ltp_prob=lp, intensities=inten_b, seeds=seeds, n_steps=T)
    windows = encode_windows_host(seeds, inten, T, W)
    trains_b = jnp.broadcast_to(windows, (B,) + windows.shape)
    rfs_w, counts_w = train_stream_batch(
        eng, snn_regfile_batch(wts_b, [4, 5, 6]), trains_b, teach_b,
        ltp_prob=lp)
    for a, b in zip(rfs_i, rfs_w):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(counts_i),
                                  np.asarray(counts_w))


def test_stream_drivers_reject_ambiguous_inputs():
    weights, inten, teach, seeds = _stream_operands(6)
    eng = SNNEngine(SNNEnginePlan(encode="kernel", **KW))
    rf = snn_regfile(weights)
    with pytest.raises(ValueError):
        train_stream(eng, rf, teach=teach)           # neither
    with pytest.raises(ValueError):
        train_stream(eng, rf, teach=teach, intensities=inten)  # no n_steps


def test_trainer_mesh_shape_identity():
    """encode="kernel" training through a (1, 1) grid == the local run,
    bit-exactly, in both train modes."""
    imgs, labels = make_digits(24, seed=9)
    for mode in ("active", "parallel"):
        cfg = SNNTrainConfig(n_neurons=20, epochs=1, n_steps=8,
                             encode="kernel", train_mode=mode)
        m_local = train(cfg, imgs, labels)
        m_grid = train(dataclasses.replace(cfg, mesh_shape=(1, 1)),
                       imgs, labels)
        np.testing.assert_array_equal(np.asarray(m_local.weights),
                                      np.asarray(m_grid.weights))


def test_classify_intensity_matches_prepacked():
    """classify() from intensities == classify() from the same samples
    host counter-encoded, for a kernel-encode model."""
    imgs, labels = make_digits(30, seed=7)
    cfg = SNNTrainConfig(n_neurons=10, epochs=1, n_steps=8,
                         encode="kernel")
    model = train(cfg, imgs, labels)
    inten = quantize_intensities(jnp.asarray(imgs, jnp.float32))
    seeds = sample_seeds(123, len(imgs))
    pred_i = classify(model, intensities=inten, seeds=seeds)
    windows = encode_windows_host(seeds, inten, cfg.n_steps, cfg.words)
    pred_w = classify(model, windows)
    np.testing.assert_array_equal(np.asarray(pred_i),
                                  np.asarray(pred_w))
